"""Capability registry + SearchBackend protocol (the unified backend API).

Parity suite: every registered backend — inverted store or self-index
adapter — must return the same word / AND / phrase answers as a raw NumPy
reference over a small repetitive collection, through the same index /
engine API.  Plus the registry crash paths: unknown names and stray build
kwargs are clear ValueErrors.
"""

import numpy as np
import pytest

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.core.registry import (
    ALL_CAPABILITIES,
    FAMILY_INVERTED,
    FAMILY_SELFINDEX,
    CAP_EXTRACT,
    CAP_SHIFTED_INTERSECT,
    backend_names,
    build_backend,
    capabilities_of,
    get_backend_spec,
)
from repro.data import generate_collection
from repro.data.text import is_word_token, tokenize
from repro.serving.engine import QueryEngine

ALL_BACKENDS = backend_names()
INVERTED = backend_names(family=FAMILY_INVERTED)
SELFINDEX = backend_names(family=FAMILY_SELFINDEX)


@pytest.fixture(scope="module")
def tiny_collection():
    return generate_collection(n_articles=2, versions_per_article=4,
                               words_per_doc=50, seed=13)


def brute_docs(docs, words):
    out = []
    for d, doc in enumerate(docs):
        toks = {t.lower() for t in tokenize(doc) if is_word_token(t)}
        if all(w in toks for w in words):
            out.append(d)
    return np.asarray(out, dtype=np.int64)


def brute_phrase(stream, ids):
    m = len(ids)
    return np.asarray([p for p in range(len(stream) - m + 1)
                       if all(stream[p + j] == ids[j] for j in range(m))], np.int64)


# ----------------------------------------------------------------------
# registry metadata + crash paths
# ----------------------------------------------------------------------
def test_registry_families_complete():
    assert len(INVERTED) == 20  # the paper's store zoo + the mined rlz
    assert "rlz" in INVERTED
    assert set(SELFINDEX) >= {"rlcsa", "wcsa", "lz77_idx", "lzend_idx"}
    assert set(ALL_BACKENDS) == set(INVERTED) | set(SELFINDEX)


def test_unknown_backend_is_value_error():
    with pytest.raises(ValueError, match="unknown backend 'nope'.*repair_skip"):
        build_backend("nope", [np.arange(3)])
    with pytest.raises(ValueError, match="registered backends"):
        NonPositionalIndex.build(["a b c"], store="not_a_store")
    with pytest.raises(ValueError, match="registered backends"):
        from repro.core.registry import restore_backend

        restore_backend("definitely_missing", {})


def test_bad_build_kwargs_are_value_error():
    lists = [np.arange(4, dtype=np.int64), np.asarray([1, 3], dtype=np.int64)]
    with pytest.raises(ValueError, match="unexpected build kwargs.*accepted: k"):
        build_backend("vbyte_cm", lists, sample_every=8)
    with pytest.raises(ValueError, match="unexpected build kwargs"):
        NonPositionalIndex.build(["a b c d"], store="vbyte", bogus=1)
    # valid kwargs still forward uniformly through the registry
    st = build_backend("vbyte_cm", lists, k=4)
    assert np.array_equal(st.get_list(0), lists[0])


def test_selfindex_needs_stream():
    with pytest.raises(ValueError, match="self-index.*token"):
        build_backend("rlcsa", [np.arange(3)])


def test_unknown_backend_error_lists_every_registered_name():
    """The PR-2 contract: the unknown-name ValueError names the live
    registry, not a subset — a user can copy any listed name and proceed."""
    with pytest.raises(ValueError) as ei:
        build_backend("nope", [np.arange(3)])
    msg = str(ei.value)
    for name in ALL_BACKENDS:
        assert name in msg, f"{name!r} missing from: {msg}"


def test_bad_kwargs_error_lists_accepted_names():
    """The stray-kwarg ValueError names both the offender and the full
    accepted set (or says there is none)."""
    lists = [np.arange(4, dtype=np.int64)]
    with pytest.raises(ValueError) as ei:
        build_backend("repair_skip_st", lists, window=9, B=4)
    msg = str(ei.value)
    assert "window" in msg and "accepted: B" in msg
    # backends with no build kwargs say so instead of listing nothing
    with pytest.raises(ValueError, match=r"accepted: \(none\)"):
        build_backend("vbyte", lists, k=3)


def test_index_build_propagates_registry_errors():
    """Both index builders surface the same registry ValueErrors eagerly
    (before any tokenization work)."""
    for builder in (NonPositionalIndex.build, PositionalIndex.build):
        with pytest.raises(ValueError, match="registered backends.*vbyte"):
            builder(["a b c"], store="definitely_missing")
        with pytest.raises(ValueError, match="unexpected build kwargs.*accepted: sample_rate"):
            builder(["a b c"], store="rlcsa", sample_rate_typo=8)


def test_declared_capabilities_are_valid_and_match_instances(tiny_collection):
    for name in ALL_BACKENDS:
        spec = get_backend_spec(name)
        assert spec.capabilities <= ALL_CAPABILITIES
        idx = PositionalIndex.build(tiny_collection.docs[:3], store=name)
        assert capabilities_of(idx.store) == spec.capabilities, name


# ----------------------------------------------------------------------
# parity: every backend vs the NumPy reference
# ----------------------------------------------------------------------
@pytest.mark.parametrize("store", ALL_BACKENDS)
def test_nonpositional_parity(tiny_collection, store):
    docs = tiny_collection.docs
    idx = NonPositionalIndex.build(docs, store=store)
    words = [w for w in idx.vocab.id_to_token[:16]]
    for q in ([words[2]], [words[1], words[5]], [words[0], words[3], words[7]]):
        ref = brute_docs(docs, q)
        got = idx.query_and(q) if len(q) > 1 else idx.query_word(q[0])
        assert np.array_equal(np.sort(np.unique(got)), ref), (store, q)
    assert idx.size_in_bits > 0


@pytest.mark.parametrize("store", ALL_BACKENDS)
def test_positional_phrase_parity(tiny_collection, store):
    docs = tiny_collection.docs
    idx = PositionalIndex.build(docs, store=store, keep_text=True)
    stream = idx.token_stream
    toks = tokenize(docs[0])
    for ph in ([toks[0]], toks[1:3], toks[4:8]):
        ids = [idx.token_id(t) for t in ph]
        assert all(i is not None for i in ids)
        ref = brute_phrase(stream, ids)
        got = np.sort(np.asarray(idx.query_phrase(list(ph))))
        assert np.array_equal(got, ref), (store, ph)


@pytest.mark.parametrize("store", SELFINDEX)
def test_selfindex_extract_roundtrip(tiny_collection, store):
    """`extract` capability: the token stream is recoverable from the index."""
    idx = PositionalIndex.build(tiny_collection.docs[:3], store=store, keep_text=True)
    assert CAP_EXTRACT in capabilities_of(idx.store)
    lo, hi = 5, 25
    assert np.array_equal(idx.store.extract(lo, hi), idx.token_stream[lo : hi + 1])


# ----------------------------------------------------------------------
# cross-family agreement through the unified engine
# ----------------------------------------------------------------------
@pytest.mark.parametrize("store", ["rlcsa", "lzend_idx"])
def test_engine_selfindex_matches_inverted(tiny_collection, store):
    """Acceptance: word and phrase queries against self-index backends go
    through the same plan/execute API and equal the inverted answers."""
    docs = tiny_collection.docs
    ref = QueryEngine(NonPositionalIndex.build(docs, store="repair_skip"),
                      positional=PositionalIndex.build(docs, store="repair_skip"))
    eng = QueryEngine(NonPositionalIndex.build(docs, store=store),
                      positional=PositionalIndex.build(docs, store=store))
    assert CAP_SHIFTED_INTERSECT in capabilities_of(eng.index.store)
    words = [w for w in ref.index.vocab.id_to_token[:12]]
    ph = tokenize(docs[0])[2:5]
    queries = [words[1], f"{words[1]} {words[4]}", '"' + " ".join(ph) + '"',
               f"top3: {words[1]} {words[4]}", "xyzzy-not-a-word"]
    for q in queries:
        plan = eng.planner.plan(q)
        assert plan.route == "host"
        got, want = eng.execute(q), ref.execute(q)
        assert np.array_equal(np.sort(np.asarray(got)), np.sort(np.asarray(want))), (store, q)
    assert eng.planner.plan(f"{words[1]} {words[4]}").strategy == "self-locate"


def test_partitioned_from_index_any_backend(tiny_collection):
    """The sharded layout builds from any backend through the protocol."""
    from repro.serving.partitioned import PartitionedAnchoredIndex

    docs = tiny_collection.docs
    idx = NonPositionalIndex.build(docs, store="vbyte_st")
    pidx = PartitionedAnchoredIndex.from_index(idx, n_shards=2)
    assert pidx.n_shards == 2
    assert int(pidx.doc_bounds[-1]) == idx.n_docs
    # positional sharding cuts at document boundaries
    p = PositionalIndex.build(docs, store="vbyte")
    ppidx = PartitionedAnchoredIndex.from_index(p, n_shards=2)
    assert int(ppidx.doc_bounds[1]) in p.doc_starts
    assert int(ppidx.doc_bounds[-1]) == p.n_tokens
