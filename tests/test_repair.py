"""Re-Pair grammar invariants + skipping search (paper §4)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline fallback: deterministic examples
    from hypothesis_fallback import given, settings, st

from repro.core.dgaps import to_dgaps
from repro.core.intersect import intersect_repair_skip, repair_intersect_multi
from repro.core.repair import RePairStore, pack_rules, repair_compress


def test_grammar_expansion_identity(rep_lists):
    store = RePairStore.build(rep_lists)
    for i, l in enumerate(rep_lists):
        assert np.array_equal(store.get_list(i), l), i


def test_phrase_sums_match_expansions(rep_lists):
    store = RePairStore.build(rep_lists)
    p = store.packed
    for k in range(len(p.sums)):
        sym = p.u + 1 + int(p.rule_pos[k])
        gaps = store.expand_symbol(sym)
        assert gaps.sum() == p.sums[k]
        assert len(gaps) == p.lens[k]


def test_depth_is_logarithmic(rep_lists):
    store = RePairStore.build(rep_lists)
    p = store.packed
    if len(p.lens):
        max_len = int(p.lens.max())
        # paper §4.4 assumption (2): rule depth O(log expansion)
        assert p.max_depth <= 2 * max(1, int(np.ceil(np.log2(max_len + 1)))) + 2


def test_contains_matches_membership(rep_lists):
    store = RePairStore.build(rep_lists)
    for i in (0, 5, 11):
        s = set(rep_lists[i].tolist())
        for x in range(0, 2000, 7):
            assert store.contains(i, x) == (x in s), (i, x)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_separators_never_merged(seed):
    """Phrases must not span lists (paper §4: unique separators)."""
    rng = np.random.default_rng(seed)
    lists = [np.unique(rng.integers(0, 500, rng.integers(1, 60))) for _ in range(5)]
    store = RePairStore.build(lists)
    for i, l in enumerate(lists):
        assert np.array_equal(store.get_list(i), l)


def test_skip_intersection_exact(rep_lists):
    store = RePairStore.build(rep_lists, variant="skip")
    rng = np.random.default_rng(1)
    for _ in range(20):
        ids = rng.choice(len(rep_lists), size=3, replace=False).tolist()
        ref = np.intersect1d(np.intersect1d(rep_lists[ids[0]], rep_lists[ids[1]]), rep_lists[ids[2]])
        got = repair_intersect_multi(store, ids)
        assert np.array_equal(got, ref)


def test_skip_visits_sublinear_ops(rep_lists):
    """Theorem 1 flavor: compressed-domain ops << decompressed comparisons
    when intersecting a short list against a long compressed one."""
    store = RePairStore.build(rep_lists, variant="skip")
    lengths = [store.list_length(i) for i in range(store.n_lists)]
    long_i = int(np.argmax(lengths))
    short_cand = rep_lists[long_i][:: max(1, len(rep_lists[long_i]) // 8)][:8]
    store.op_counter = 0
    intersect_repair_skip(store, long_i, short_cand)
    n = lengths[long_i]
    n_prime = int(store.c_offsets[long_i + 1] - store.c_offsets[long_i])
    m = len(short_cand)
    # O(n' + m(1 + log(n/m))) with a generous constant
    bound = 8 * (n_prime + m * (1 + np.log2(max(2, n / max(1, m)))) ) + 64
    assert store.op_counter <= bound, (store.op_counter, bound, n, n_prime)


def test_sampling_variants_agree(rep_lists):
    base = RePairStore.build(rep_lists, variant="skip")
    ids = [0, 4, 9]
    ref = repair_intersect_multi(base, ids)
    for sampling in (("cm", 2), ("cm", 64), ("st", 16), ("st", 256)):
        st_store = RePairStore.build(rep_lists, variant="skip", sampling=sampling)
        assert np.array_equal(repair_intersect_multi(st_store, ids), ref), sampling


def test_size_accounting_positive(rep_lists):
    for variant in ("plain", "skip"):
        store = RePairStore.build(rep_lists, variant=variant)
        assert store.size_in_bits > 0
    skip = RePairStore.build(rep_lists, variant="skip")
    plain = RePairStore.build(rep_lists, variant="plain")
    # skip data adds the phrase sums: slightly larger, never smaller
    assert skip.size_in_bits >= plain.size_in_bits * 0.9
