"""Result-cache correctness: never a stale answer, precise invalidation.

The cache memoizes answers under (physical-plan structure, concrete terms,
segment shape).  These tests pin the contract down against a live
:class:`~repro.core.writer.IndexWriter` collection: repeated traffic hits
the cache and stays byte-identical to a cold session; ``refresh()`` after
``writer.commit()`` invalidates **exactly** the entries whose terms can
occur in the new segment (the rest keep serving from cache); ``top3:`` and
``top5:`` over the same terms are distinct entries; a compaction clears
everything.  The headline property throughout: zero drift versus a session
opened cold after every commit.
"""

import asyncio
import os

import numpy as np
import pytest

from repro.core.writer import IndexWriter
from repro.serving.frontend import FrontendConfig, MicroBatchFrontend
from repro.serving.session import Session

BASE_SEED = int(os.environ.get("REPRO_DIFF_SEED", "20260727"))

# controlled vocabulary — none of these are tokenizer stopwords, none
# hyphenated, so each word is exactly one term in exactly the docs below
DOCS_V1 = [
    "alpha beta gamma alpha beta",
    "alpha gamma delta epsilon gamma",
    "zebra quartz zebra nickel quartz",
    "beta delta nickel epsilon beta",
]
DOCS_V2 = [  # second commit: mentions alpha/beta/gamma, never zebra/quartz
    "alpha beta alpha gamma beta",
    "delta alpha epsilon beta gamma",
]


def make_writer(tmp_path, docs=DOCS_V1, store="vbyte"):
    w = IndexWriter(tmp_path / "col", store=store, positional=True)
    w.add_documents(docs)
    w.commit()
    return w


def submit_all(session, queries, config=None):
    """One frontend lifetime: submit each query in order, return results
    plus the frontend (already closed) for metric inspection."""
    config = config or FrontendConfig(max_batch=4, max_delay=0.001)

    async def main():
        async with MicroBatchFrontend(session, config) as fe:
            results = [await fe.submit(q) for q in queries]
            return results, fe

    return asyncio.run(main())


def cold_answers(path, queries):
    return Session.open(path, device=False).execute(queries)


def test_repeat_traffic_hits_cache_and_matches_cold(tmp_path):
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)
    queries = ["alpha beta", "docs: gamma", 'top3: alpha gamma',
               '"zebra quartz"', "docs: zebra"]
    traffic = queries * 3  # repeated pool, like real serving traffic
    results, fe = submit_all(session, traffic)
    cache = fe.metrics()["cache"]
    assert cache["hit_rate"] > 0, cache
    assert cache["hits"] >= 2 * len(queries), cache
    reference = cold_answers(w.path, traffic)
    for q, res, ref in zip(traffic, results, reference):
        assert np.array_equal(np.asarray(res), np.asarray(ref)), \
            f"(seed={BASE_SEED}, query={q!r}): cached {res} != cold {ref}"


def test_topk_variants_are_distinct_entries(tmp_path):
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)
    queries = ["top3: alpha beta", "top5: alpha beta",
               "docs-top3: alpha beta", "docs-top5: alpha beta"]
    # distinct result keys -> four entries, no cross-talk
    keys = [session.result_key(q) for q in queries]
    assert len(set(keys)) == len(keys), keys
    results, fe = submit_all(session, queries * 2)
    assert fe.metrics()["cache"]["entries"] == len(queries)
    reference = cold_answers(w.path, queries * 2)
    for q, res, ref in zip(queries * 2, results, reference):
        assert np.array_equal(np.asarray(res), np.asarray(ref)), \
            f"query={q!r}: k-variant entries crossed"


def test_result_key_carries_segment_shape(tmp_path):
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)
    before = session.result_key("docs: alpha")
    w.add_documents(DOCS_V2)
    w.commit()
    session.refresh()
    after = session.result_key("docs: alpha")
    assert before != after
    assert before[:2] == after[:2]  # same plan structure + terms
    assert before[2] != after[2]  # the segment shape moved


def test_commit_refresh_invalidates_exactly_affected_entries(tmp_path):
    """The precise-invalidation contract, end to end: after a commit that
    mentions alpha but never zebra, the zebra entry keeps serving from
    cache, the alpha entries are recomputed — and *every* answer equals a
    cold open of the committed state (zero stale serves)."""
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)

    async def main():
        fe = MicroBatchFrontend(session,
                                FrontendConfig(max_batch=4, max_delay=0.001))
        warm = ["docs: alpha", "docs: zebra", "alpha beta", '"zebra quartz"']
        before = [np.asarray(r) for r in
                  [await fe.submit(q) for q in warm]]
        assert len(fe.cache) == len(warm)

        w.add_documents(DOCS_V2)  # alpha/beta/gamma only — zebra untouched
        w.commit()
        opened = await fe.refresh()
        assert opened == 1  # one appended segment
        cache = fe.cache.metrics()
        # alpha-only entries die; zebra entries migrate to the new shape
        assert cache["invalidated"] == 2, cache
        assert cache["migrated"] == 2, cache
        assert cache["entries"] == 2, cache

        hits0 = fe.cache.hits
        after = {q: np.asarray(await fe.submit(q)) for q in warm}
        # the zebra queries were served straight from the migrated entries
        assert fe.cache.hits >= hits0 + 2, fe.cache.metrics()
        return before, warm, after

    before, warm, after = asyncio.run(main())
    reference = dict(zip(warm, cold_answers(w.path, warm)))
    for q in warm:
        assert np.array_equal(after[q], np.asarray(reference[q])), \
            f"(seed={BASE_SEED}, query={q!r}): stale serve after commit+refresh"
    # and the commit really changed the alpha answers (the invalidation
    # wasn't vacuous): DOCS_V2 adds docs 4 and 5 containing alpha
    before_alpha = before[warm.index("docs: alpha")]
    assert not np.array_equal(before_alpha, after["docs: alpha"])
    assert set(after["docs: alpha"].tolist()) >= {4, 5}
    # zebra listing is byte-identical before and after
    assert np.array_equal(before[warm.index("docs: zebra")],
                          after["docs: zebra"])


def test_commit_invalidates_exactly_affected_ranked_entries(tmp_path):
    """Ranked entries are disjunctive: a commit mentioning *any* of a
    ``rank<k>:`` query's terms invalidates it (here ``rank3: alpha zebra``
    dies because the new segment knows alpha, even though it never mentions
    zebra — the conjunctive all-terms rule would wrongly keep it), while a
    ranked entry over terms the new segment doesn't know keeps serving from
    cache.  Every post-commit answer equals a cold open."""
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)

    async def main():
        fe = MicroBatchFrontend(session,
                                FrontendConfig(max_batch=4, max_delay=0.001))
        warm = ["rank3: alpha zebra", "rank2: zebra quartz"]
        before = [np.asarray(r) for r in [await fe.submit(q) for q in warm]]
        assert len(fe.cache) == len(warm)

        w.add_documents(DOCS_V2)  # alpha/beta/gamma only — zebra untouched
        w.commit()
        await fe.refresh()
        cache = fe.cache.metrics()
        assert cache["invalidated"] == 1, cache
        assert cache["migrated"] == 1, cache

        hits0 = fe.cache.hits
        after = {q: np.asarray(await fe.submit(q)) for q in warm}
        # the zebra-quartz ranking was served straight from the migrated entry
        assert fe.cache.hits == hits0 + 1, fe.cache.metrics()
        return before, warm, after

    before, warm, after = asyncio.run(main())
    reference = dict(zip(warm, cold_answers(w.path, warm)))
    for q in warm:
        assert np.array_equal(after[q], np.asarray(reference[q])), \
            f"(seed={BASE_SEED}, query={q!r}): stale ranked serve after commit"
    # the commit really moved the alpha ranking: docs 4 and 5 mention alpha
    assert not np.array_equal(before[0], after["rank3: alpha zebra"])
    assert np.array_equal(before[1], after["rank2: zebra quartz"])


def test_plain_refresh_drives_invalidation_too(tmp_path):
    """Invalidation hangs off Session.refresh() itself — a caller who
    never touches frontend.refresh() still gets a correct cache."""
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)

    async def main():
        async with MicroBatchFrontend(
                session, FrontendConfig(max_batch=2, max_delay=0.001)) as fe:
            await fe.submit("docs: alpha")
            await fe.submit("docs: zebra")
            w.add_documents(DOCS_V2)
            w.commit()
            session.refresh()  # NOT fe.refresh()
            m = fe.cache.metrics()
            assert m["invalidated"] == 1 and m["migrated"] == 1, m
            res = np.asarray(await fe.submit("docs: alpha"))
            return res

    res = asyncio.run(main())
    assert np.array_equal(res, np.asarray(cold_answers(w.path,
                                                       ["docs: alpha"])[0]))


def test_compaction_clears_all_entries(tmp_path):
    w = make_writer(tmp_path)
    w.add_documents(DOCS_V2)
    w.commit()
    session = Session.open(w.path, device=False)

    async def main():
        fe = MicroBatchFrontend(session,
                                FrontendConfig(max_batch=4, max_delay=0.001))
        queries = ["docs: zebra", "docs: alpha", '"zebra quartz"']
        before = [np.asarray(r) for r in
                  [await fe.submit(q) for q in queries]]
        assert len(fe.cache) == len(queries)
        w.compact()  # rewrites the segment set: nothing may survive
        await fe.refresh()
        m = fe.cache.metrics()
        assert m["entries"] == 0, m
        assert m["migrated"] == 0, m
        assert m["invalidated"] == len(queries), m
        after = [np.asarray(await fe.submit(q)) for q in queries]
        return queries, before, after

    queries, before, after = asyncio.run(main())
    # compaction preserves answers (same docs, one segment) — recomputed,
    # not served stale, and still correct
    reference = cold_answers(w.path, queries)
    for q, b, a, ref in zip(queries, before, after, reference):
        assert np.array_equal(a, np.asarray(ref)), f"query={q!r}"
        assert np.array_equal(b, a), f"query={q!r}: compaction changed data?"


def test_cache_disabled_still_correct(tmp_path):
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)
    queries = ["alpha beta", "docs: gamma"] * 2
    results, fe = submit_all(
        session, queries,
        FrontendConfig(max_batch=4, max_delay=0.001, cache_entries=0))
    m = fe.metrics()["cache"]
    assert m["entries"] == 0 and m["hits"] == 0, m
    reference = cold_answers(w.path, queries)
    for q, res, ref in zip(queries, results, reference):
        assert np.array_equal(np.asarray(res), np.asarray(ref))


def test_cached_arrays_are_frozen(tmp_path):
    w = make_writer(tmp_path)
    session = Session.open(w.path, device=False)
    results, fe = submit_all(session, ["docs: alpha", "docs: alpha"])
    assert results[1].flags.writeable is False
    with pytest.raises(ValueError):
        results[1][0] = 999  # a caller cannot corrupt the shared entry


def test_background_compaction_swap_under_live_traffic(tmp_path):
    """The headline storage-layer regression: ``compact_async`` swapping
    the segment set behind a frontend under continuous traffic serves
    **zero stale entries** (compaction preserves answers, so every answer
    — during the merge, across the swap, after it — must equal the cold
    reference) and **never drops an in-flight query**; the result cache
    is invalidated exactly once, at the swap."""
    import time as _time

    w = make_writer(tmp_path)
    w.add_documents(DOCS_V2)
    w.commit()
    session = Session.open(w.path, device=False)
    queries = ["docs: alpha", "docs: zebra", "alpha beta",
               '"zebra quartz"', "top3: alpha gamma"]
    reference = [np.asarray(r) for r in cold_answers(w.path, queries)]

    # slow the merge down so several traffic rounds overlap it
    orig_merge = w._merged_indexes

    def slow_merge(segments):
        _time.sleep(0.15)
        return orig_merge(segments)

    w._merged_indexes = slow_merge

    async def main():
        fe = MicroBatchFrontend(session,
                                FrontendConfig(max_batch=4, max_delay=0.001))
        for q in queries:
            await fe.submit(q)  # warm the cache: the swap must clear these
        version_before = session.data_version
        handle = w.compact_async(on_swap=fe.refresh_threadsafe)
        served = []
        while not handle.done:
            # gather raises if any in-flight query is dropped or errored
            results = await asyncio.gather(*(fe.submit(q) for q in queries))
            served.append([np.asarray(r) for r in results])
        served.append([np.asarray(await fe.submit(q)) for q in queries])
        metrics = fe.cache.metrics()
        swaps = session.data_version - version_before
        await fe.close()
        return handle, served, metrics, swaps

    handle, served, metrics, swaps = asyncio.run(main())
    handle.wait(60)
    assert len(served) >= 2  # traffic genuinely overlapped the merge
    assert swaps == 1  # the cache invalidation fired exactly once
    assert metrics["invalidated"] >= len(queries), metrics
    assert len(session._segments) == 1  # the swap reached the session
    for round_i, results in enumerate(served):
        assert len(results) == len(queries)  # nothing dropped
        for q, res, ref in zip(queries, results, reference):
            assert np.array_equal(res, ref), \
                (f"(seed={BASE_SEED}, round={round_i}, query={q!r}): stale "
                 f"serve across the compaction swap: {res} != {ref}")
