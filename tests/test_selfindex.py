"""Self-indexes: locate/count/extract vs naive scan (paper Appendix A)."""

import numpy as np
import pytest

from repro.core.selfindex import LZ77Index, LZEndIndex, RLCSA, SLPIndex, WCSA, WSLPIndex


def reptext(seed, nb=100, nc=6, sigma=6, noise=0.04):
    rng = np.random.default_rng(seed)
    base = rng.integers(1, sigma, nb)
    parts = [base]
    for _ in range(nc):
        c = base.copy()
        m = rng.random(nb) < noise
        c[m] = rng.integers(1, sigma, m.sum())
        parts.append(c)
    return np.concatenate(parts)


def brute(t, p):
    m = len(p)
    return np.asarray([i for i in range(len(t) - m + 1)
                       if np.array_equal(t[i : i + m], p)], np.int64)


ALL = [RLCSA, WCSA, LZ77Index, LZEndIndex, SLPIndex, WSLPIndex]


@pytest.mark.parametrize("cls", ALL)
def test_locate_matches_brute(cls):
    t = reptext(11)
    idx = cls(t)
    rng = np.random.default_rng(1)
    pats = [t[0:1], t[5:8], t[60:66], np.asarray([4, 4, 4, 4])]
    for _ in range(4):
        i = int(rng.integers(0, len(t) - 6))
        pats.append(t[i : i + int(rng.integers(2, 6))])
    for p in pats:
        assert np.array_equal(idx.locate(p), brute(t, p)), (cls.__name__, p.tolist())
        assert idx.count(p) == len(brute(t, p))


@pytest.mark.parametrize("cls", ALL)
def test_extract(cls):
    t = reptext(12)
    idx = cls(t)
    rng = np.random.default_rng(2)
    for _ in range(8):
        i = int(rng.integers(0, len(t) - 1))
        j = int(rng.integers(i, min(len(t) - 1, i + 40)))
        assert np.array_equal(idx.extract(i, j), t[i : j + 1]), cls.__name__


@pytest.mark.parametrize("cls", ALL)
def test_absent_pattern(cls):
    t = reptext(13, sigma=4)
    idx = cls(t)
    p = np.asarray([7, 8, 9])  # symbols never used
    assert idx.count(p) == 0


def test_sizes_reflect_repetitiveness():
    """More repetitive text -> smaller LZ77 self-index."""
    t_rep = reptext(14, nb=80, nc=14, noise=0.01)
    t_rand = np.random.default_rng(3).integers(1, 6, len(t_rep))
    rep_idx, rand_idx = LZ77Index(t_rep), LZ77Index(t_rand)
    assert rep_idx.size_in_bits < rand_idx.size_in_bits
