"""Anchored index + batched serving engine (the uihrdc architecture)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anchors import AnchoredIndex, build_anchored, member_batch
from repro.serving.engine import make_uihrdc_serve_step


@pytest.fixture(scope="module")
def lists(rep_lists=None):
    rng = np.random.default_rng(7)
    out = []
    for _ in range(30):
        present = np.repeat(rng.random(80) < 0.35, 20) ^ (rng.random(1600) < 0.02)
        l = np.flatnonzero(present).astype(np.int64)
        out.append(l if len(l) else np.asarray([1], dtype=np.int64))
    return out


@pytest.fixture(scope="module")
def aidx(lists):
    return build_anchored(lists)


def test_member_batch_exhaustive(lists, aidx):
    xs = np.arange(1700)
    for i in (0, 9, 29):
        got = np.asarray(member_batch(aidx, jnp.full(len(xs), i, jnp.int32),
                                      jnp.asarray(xs, jnp.int32)))
        assert np.array_equal(got, np.isin(xs, lists[i])), i


def test_member_batch_mixed_lists(lists, aidx):
    rng = np.random.default_rng(1)
    ids = rng.integers(0, len(lists), 500).astype(np.int32)
    vals = rng.integers(0, 1700, 500).astype(np.int32)
    got = np.asarray(member_batch(aidx, jnp.asarray(ids), jnp.asarray(vals)))
    ref = np.asarray([int(v) in set(lists[i].tolist()) for i, v in zip(ids, vals)])
    assert np.array_equal(got, ref)


def test_serve_step_and_queries(lists, aidx):
    serve = jax.jit(make_uihrdc_serve_step(max_terms=3))
    arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
              "expand": aidx.expand, "expand_valid": aidx.expand_valid,
              "lengths": aidx.lengths}
    qt = jnp.asarray([[2, 7, 0], [11, 3, 19], [5, 0, 0]], jnp.int32)
    ql = jnp.asarray([2, 3, 1], jnp.int32)
    vals, mask = serve(arrays, qt, ql)
    for qi, terms in enumerate([[2, 7], [11, 3, 19], [5]]):
        ref = lists[terms[0]]
        for t in terms[1:]:
            ref = np.intersect1d(ref, lists[t])
        got = np.unique(np.asarray(vals[qi])[np.asarray(mask[qi])])
        cap = np.asarray(vals[qi]).max()
        assert np.array_equal(got, ref[ref <= cap]), qi


def test_anchor_sizes(aidx):
    assert aidx.device_bytes() > 0
    assert aidx.anchors.shape[0] + 1 >= aidx.c_offsets.shape[0]


def test_partitioned_index_matches_global(lists):
    """Document-partitioned serving == global AND results (manual per-shard
    loop; the shard_map path is exercised in test_distributed)."""
    from repro.serving.partitioned import PartitionedAnchoredIndex, _local_serve, merge_results

    n_docs = 1600
    pidx = PartitionedAnchoredIndex.build(lists, n_docs=n_docs, n_shards=4)
    qt = jnp.asarray([[2, 7], [11, 3], [5, 5]], jnp.int32)
    ql = jnp.asarray([2, 2, 1], jnp.int32)
    all_vals, all_mask = [], []
    for s in range(4):
        local = {k: np.asarray(v[s]) for k, v in pidx.arrays.items() if k != "doc_base"}
        local = {k: jnp.asarray(v) for k, v in local.items()}
        local["doc_base"] = pidx.arrays["doc_base"][s : s + 1]
        vals, mask = _local_serve(local, qt, ql, max_terms=2)
        all_vals.append(np.asarray(vals))
        all_mask.append(np.asarray(mask))
    vals = np.stack(all_vals)
    mask = np.stack(all_mask)
    merged = merge_results(vals, mask)
    for qi, terms in enumerate([[2, 7], [11, 3], [5]]):
        ref = lists[terms[0]]
        for t in terms[1:]:
            ref = np.intersect1d(ref, lists[t])
        # per-shard candidate caps: compare within each shard's cap
        got = merged[qi]
        ok = np.isin(got, ref).all()
        assert ok, (qi, got[:10], ref[:10])
        # no hit lost below the per-shard caps
        for s in range(4):
            lo, hi = pidx.doc_bounds[s], pidx.doc_bounds[s + 1]
            cap = vals[s, qi].max()
            expect = ref[(ref >= lo) & (ref < hi) & (ref <= cap)]
            shard_got = np.unique(vals[s, qi][mask[s, qi]])
            assert np.array_equal(shard_got, expect), (qi, s)
