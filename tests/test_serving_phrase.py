"""Batched device phrase/top-k serving vs the host positional index.

The acceptance bar for the batched serving subsystem: identical
(doc, offset) phrase results to host ``PositionalIndex.query_phrase`` on a
repetitive versioned collection, across several list stores, including
driving lists longer than one 64-candidate window.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.data.text import tokenize
from repro.serving.engine import (
    MAX_CAND_ROWS,
    BatchedServer,
    QueryEngine,
    make_serve_step,
    parse_query,
)

STORES = ["repair_skip", "vbyte", "elias_fano"]


@pytest.fixture(scope="module")
def col():
    return generate_collection(n_articles=6, versions_per_article=15,
                               words_per_doc=120, seed=11)


@pytest.fixture(scope="module")
def phrase_queries(col):
    rng = np.random.default_rng(3)
    out = []
    for _ in range(12):
        doc = col.docs[int(rng.integers(len(col.docs)))]
        toks = tokenize(doc)
        i = int(rng.integers(0, max(1, len(toks) - 3)))
        out.append(toks[i : i + 2 + int(rng.integers(2))])
    out.append(["zzz", "not-in-vocab"])  # unknown terms -> empty
    return out


@pytest.mark.parametrize("store", STORES)
def test_phrase_matches_host(col, phrase_queries, store):
    pidx = PositionalIndex.build(col.docs, store=store)
    server = BatchedServer.from_index(pidx)
    got = server.phrase(phrase_queries)
    for q, dev_pos in zip(phrase_queries, got):
        host_pos = np.sort(np.asarray(pidx.query_phrase(q)))
        assert np.array_equal(dev_pos, host_pos), (store, q)
        # identical (doc, offset) pairs, not just raw positions
        hd, ho = pidx.positions_to_docs(host_pos)
        dd, do = pidx.positions_to_docs(dev_pos)
        assert np.array_equal(hd, dd) and np.array_equal(ho, do), (store, q)


def test_phrase_step_covers_long_lists():
    """Driving lists longer than one candidate window are served exactly
    (the old MAX_CAND_ROWS=64 truncation would drop the tail)."""
    rng = np.random.default_rng(5)
    n = 40_000
    # incompressible positional lists: ~1 C-entry per posting after Re-Pair,
    # so ~6000 postings >> 64 candidate rows
    a = np.sort(rng.choice(n, 6000, replace=False)).astype(np.int64)
    b = np.sort(np.unique(np.concatenate(
        [a[::2] + 1, rng.choice(n, 3000)]))).astype(np.int64)
    c = np.sort(np.unique(np.concatenate(
        [a[::3] + 2, rng.choice(n, 2000)]))).astype(np.int64)
    from repro.core.anchors import build_anchored

    aidx = build_anchored([a, b, c])
    c_off = np.asarray(aidx.c_offsets)
    assert c_off[1] - c_off[0] > MAX_CAND_ROWS, "driving list must span >1 window"

    ref = a[np.isin(a + 1, b) & np.isin(a + 2, c)]
    arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
              "expand": aidx.expand, "expand_valid": aidx.expand_valid,
              "lengths": aidx.lengths}
    import jax

    step = jax.jit(make_serve_step(max_terms=3, mode="phrase"))
    qt = jnp.asarray([[0, 1, 2]], jnp.int32)
    ql = jnp.asarray([3], jnp.int32)
    hits = []
    n_win = -(-int(c_off[1] - c_off[0]) // MAX_CAND_ROWS)
    assert n_win > 1
    for w in range(n_win):
        vals, mask = step(arrays, qt, ql, w * MAX_CAND_ROWS)
        hits.append(np.asarray(vals)[0][np.asarray(mask)[0]])
    got = np.unique(np.concatenate(hits))
    assert np.array_equal(got, ref)
    # ... and the truncated single window would NOT have been enough
    assert len(hits[0]) < len(ref) or len(ref) == 0


def test_topk_matches_host_ranked_and(col):
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    server = BatchedServer.from_index(idx)
    engine = QueryEngine(idx, server=server)
    rng = np.random.default_rng(9)
    words = [w for w in idx.vocab.id_to_token[:150]]
    queries = [[words[int(rng.integers(len(words)))] for _ in range(2)]
               for _ in range(10)]
    dev = server.topk(queries, k=5)
    for q, d in zip(queries, dev):
        host = engine.ranked_and(q, k=5)
        assert np.array_equal(np.asarray(d), np.asarray(host)), q


def test_planner_routes_mixed_batch(col):
    idx = NonPositionalIndex.build(col.docs, store="repair_skip")
    pidx = PositionalIndex.build(col.docs, store="repair_skip")
    engine = QueryEngine(idx, positional=pidx,
                         server=BatchedServer.from_index(idx),
                         positional_server=BatchedServer.from_index(pidx))
    toks = tokenize(col.docs[0])[:3]
    w = [t for t in idx.vocab.id_to_token[:10]][:2]
    queries = [w[0], f"{w[0]} {w[1]}", '"' + " ".join(toks) + '"',
               f"top3: {w[0]} {w[1]}"]
    kinds = [engine.planner.plan(q).query.kind for q in queries]
    assert kinds == ["word", "and", "phrase", "topk"]
    routes = [engine.planner.plan(q).route for q in queries]
    assert routes[0] == "host" and set(routes[1:]) == {"device"}
    res = engine.batch(queries)
    host = QueryEngine(idx, positional=pidx).batch(queries)
    for r, h in zip(res, host):
        assert np.array_equal(np.asarray(r), np.asarray(h))


def test_parse_query_forms():
    assert parse_query("a").kind == "word"
    assert parse_query("a b").kind == "and"
    assert parse_query('"a b"').kind == "phrase"
    q = parse_query("top7: a b")
    assert q.kind == "topk" and q.k == 7 and q.terms == ("a", "b")
    assert parse_query(["a"]).kind == "word"
    assert parse_query(["a", "b"]).kind == "and"
