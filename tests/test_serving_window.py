"""Windowed-sweep boundary regression guard (the PR-1 truncation bug class).

The batched device path scans MAX_CAND_ROWS C-entries of the driving list
per window and sweeps windows until the list is exhausted.  Off-by-one bugs
in that sweep bite exactly at the window size, so these tests pin driving
lists whose C-entry counts are *exactly* MAX_CAND_ROWS, MAX_CAND_ROWS ± 1,
and 3 * MAX_CAND_ROWS, and require device results identical to the host
engine.  `max_rules=0` disables grammar rounds so every posting is one
C-entry — list length == C-entry count, deterministically.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.anchors import build_anchored
from repro.core.index import NonPositionalIndex
from repro.serving.engine import (
    MAX_CAND_ROWS,
    BatchedServer,
    QueryEngine,
    make_serve_step,
)

BOUNDARY_LENGTHS = (MAX_CAND_ROWS - 1, MAX_CAND_ROWS, MAX_CAND_ROWS + 1,
                    3 * MAX_CAND_ROWS)
N_DOCS = 3 * MAX_CAND_ROWS + 8


@pytest.fixture(scope="module")
def boundary_index():
    """A collection where word ``w<L>`` occurs in exactly docs [0, L) and
    ``common`` in every doc, indexed with ``max_rules=0`` so posting-list
    length equals C-entry count exactly."""
    docs = []
    for d in range(N_DOCS):
        words = ["common"] + [f"w{L}" for L in BOUNDARY_LENGTHS if d < L]
        docs.append(" ".join(words))
    idx = NonPositionalIndex.build(docs, store="repair", max_rules=0)
    server = BatchedServer.from_index(idx)
    return idx, server


@pytest.mark.parametrize("length", BOUNDARY_LENGTHS)
def test_and_at_window_boundaries(boundary_index, length):
    idx, server = boundary_index
    wid = idx.word_id(f"w{length}")
    c_off = np.asarray(server.arrays["c_offsets"])
    assert int(c_off[wid + 1] - c_off[wid]) == length, "C-entries must equal list length"
    host = QueryEngine(idx)
    q = [f"w{length}", "common"]
    dev = server.conjunctive([q])[0]
    want = np.asarray(host.conjunctive(q))
    assert np.array_equal(dev, want), (length, len(dev), len(want))
    assert len(dev) == length  # w<L> ∩ all-docs == [0, L)
    # the sweep runs exactly ceil(L / MAX_CAND_ROWS) windows
    qt, ql, ok = server.encode([q], sort_by_length=True)
    assert server._n_windows(qt, ok) == -(-length // MAX_CAND_ROWS)


@pytest.mark.parametrize("length", BOUNDARY_LENGTHS)
def test_doclist_at_window_boundaries(boundary_index, length):
    """The device doc-listing dedup must also be window-exact."""
    idx, server = boundary_index
    host = QueryEngine(idx)
    q = [f"w{length}", "common"]
    dev = server.doclist([q])[0]
    want = host.doc_list(q)
    assert np.array_equal(dev, want), (length, len(dev), len(want))


def test_phrase_step_at_exact_window_multiple():
    """Anchored phrase probing where the driving list is an exact multiple
    of the window (no partial final window to hide truncation)."""
    n = 4 * MAX_CAND_ROWS
    a = (np.arange(n, dtype=np.int64) * 3)          # len == 4 * window
    b = a[::2] + 1                                  # phrase partner
    aidx = build_anchored([a, b], max_rules=0)
    c_off = np.asarray(aidx.c_offsets)
    assert int(c_off[1] - c_off[0]) == n
    arrays = {"anchors": aidx.anchors, "c_offsets": aidx.c_offsets,
              "expand": aidx.expand, "expand_valid": aidx.expand_valid,
              "lengths": aidx.lengths}
    step = jax.jit(make_serve_step(max_terms=2, mode="phrase"))
    qt = jnp.asarray([[0, 1]], jnp.int32)
    ql = jnp.asarray([2], jnp.int32)
    hits = []
    for w in range(-(-n // MAX_CAND_ROWS)):
        vals, mask = step(arrays, qt, ql, w * MAX_CAND_ROWS)
        hits.append(np.asarray(vals)[0][np.asarray(mask)[0]])
    got = np.unique(np.concatenate(hits))
    ref = a[np.isin(a + 1, b)]
    assert np.array_equal(got, ref)
    assert len(ref) == len(b)
