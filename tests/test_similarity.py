"""Version-structure mining: MinHash/LSH clustering vs brute-force Jaccard.

The miner's contract is statistical, so every assertion here is stated
with its error budget: a MinHash estimate over ``num_perm``
permutations has standard error ``sqrt(J(1-J)/num_perm)`` (≈ 0.0625 at
J = 0.5 with the default 64 permutations), and the tests allow a
``MARGIN`` of 0.2 — over 3σ — around the clustering threshold before
calling a disagreement with the brute-force Jaccard reference a
failure.  Every failure message carries the ``(structure, seed)`` pair
(plus the doc ids and both similarity values) so a red run shrinks to a
one-liner: regenerate the named collection and replay the named query.

Mining never reads ``article_of``; the ground-truth labels appear only
on the assertion side (purity / pair recall).
"""

import itertools

import numpy as np
import pytest

from repro.core.analyzer import Analyzer
from repro.core.index import NonPositionalIndex
from repro.core.similarity import (
    MinHashConfig,
    SimilarityIndex,
    est_jaccard,
    shingle_hashes,
    signature_matrix,
)
from repro.data import generate_collection
from repro.data.text import tokenize
from repro.serving.plan import parse_query
from repro.serving.session import Session

SEED = 7
CONFIG = MinHashConfig()  # 64 perms x 16 bands, shingle 3, threshold 0.5
#: slack around the clustering threshold before an estimate/brute
#: disagreement counts as a failure (> 3 standard errors at J = 0.5)
MARGIN = 0.2


def _term_seqs(docs):
    """Batch-local analyzed term-id sequences (what the miner consumes)."""
    an = Analyzer()
    ids: dict[str, int] = {}
    seqs = []
    for doc in docs:
        seq = [ids.setdefault(w, len(ids))
               for w in (an.normalize(t) for t in tokenize(doc))
               if w is not None]
        seqs.append(np.asarray(seq, dtype=np.int64))
    return seqs


def _brute_jaccard(seqs, k):
    """Exact pairwise Jaccard over the k-shingle sets — the reference."""
    sets = [set(shingle_hashes(s, k).tolist()) for s in seqs]
    n = len(sets)
    jac = np.zeros((n, n))
    for i in range(n):
        for j in range(i + 1, n):
            union = len(sets[i] | sets[j])
            jac[i, j] = jac[j, i] = (len(sets[i] & sets[j]) / union
                                     if union else 0.0)
    return jac


class MinedCase:
    def __init__(self, structure: str):
        self.structure = structure
        self.col = generate_collection(n_articles=5, versions_per_article=8,
                                       words_per_doc=120, edit_rate=0.02,
                                       structure=structure, seed=SEED)
        self.seqs = _term_seqs(self.col.docs)
        self.sim = SimilarityIndex.mine(self.seqs, CONFIG)
        self.jac = _brute_jaccard(self.seqs, CONFIG.shingle)

    @property
    def tag(self) -> str:
        return f"structure={self.structure!r} seed={SEED}"


@pytest.fixture(scope="module", params=["linear", "tree"],
                ids=lambda s: f"structure={s}")
def mined(request) -> MinedCase:
    return MinedCase(request.param)


# ----------------------------------------------------------------------
# acceptance: clusters recover articles without reading the labels
# ----------------------------------------------------------------------
def test_purity_recovers_articles(mined):
    purity = mined.sim.purity(mined.col.article_of)
    assert purity >= 0.9, (
        f"mined cluster purity {purity:.3f} < 0.9 at edit_rate=0.02 "
        f"({mined.tag}): labels={mined.sim.labels.tolist()} "
        f"truth={mined.col.article_of.tolist()}")


def test_pair_recall_against_ground_truth(mined):
    pairs = mined.col.similar_pairs()
    assert pairs, f"similar_pairs() empty ({mined.tag})"
    labels = mined.sim.labels
    missed = [(i, j) for i, j in pairs if labels[i] != labels[j]]
    recall = 1 - len(missed) / len(pairs)
    assert recall >= 0.9, (
        f"ground-truth pair recall {recall:.3f} < 0.9 ({mined.tag}); "
        f"first missed pairs {missed[:5]}")


def test_stats_exposes_labels(mined):
    stats = mined.col.stats()
    assert stats["article_of"] == mined.col.article_of.tolist()
    assert stats["articles"] == 5 and stats["versions"] == 40


# ----------------------------------------------------------------------
# similar: / versions-of: vs the brute-force Jaccard reference
# ----------------------------------------------------------------------
def test_similar_matches_brute_jaccard(mined):
    """Every pair > MARGIN above the threshold must be returned, nothing
    > MARGIN below it may be — the band where MinHash noise (stderr
    sqrt(J(1-J)/num_perm)) can flip the decision is excused."""
    sim, jac, thr = mined.sim, mined.jac, CONFIG.threshold
    n = sim.n_docs
    for d in range(n):
        got = set(sim.similar(d).tolist())
        for j in range(n):
            if j == d:
                continue
            if jac[d, j] >= thr + MARGIN:
                assert j in got, (
                    f"similar:{d} missed doc {j} with true Jaccard "
                    f"{jac[d, j]:.3f} >= {thr} + {MARGIN} ({mined.tag}; "
                    f"estimate {est_jaccard(sim.sigs, d, j):.3f}, "
                    f"num_perm={CONFIG.num_perm})")
            if j in got:
                assert jac[d, j] > thr - MARGIN, (
                    f"similar:{d} returned doc {j} with true Jaccard "
                    f"{jac[d, j]:.3f} <= {thr} - {MARGIN} ({mined.tag}; "
                    f"estimate {est_jaccard(sim.sigs, d, j):.3f}, "
                    f"num_perm={CONFIG.num_perm})")


def test_versions_of_matches_brute_components(mined):
    """Mined clusters bracket the brute-force transitive closure: pairs
    connected at threshold + MARGIN must share a cluster, and same-cluster
    pairs must be connected at threshold - MARGIN."""
    sim, jac, thr = mined.sim, mined.jac, CONFIG.threshold
    n = sim.n_docs

    def components(level):
        parent = list(range(n))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for i, j in itertools.combinations(range(n), 2):
            if jac[i, j] >= level:
                parent[find(i)] = find(j)
        return [find(i) for i in range(n)]

    tight, loose = components(thr + MARGIN), components(thr - MARGIN)
    for i, j in itertools.combinations(range(n), 2):
        same = sim.labels[i] == sim.labels[j]
        if tight[i] == tight[j]:
            assert same, (
                f"docs {i},{j} are brute-connected at Jaccard >= "
                f"{thr + MARGIN} but mined into different clusters "
                f"({mined.tag})")
        if same:
            assert loose[i] == loose[j], (
                f"docs {i},{j} share a mined cluster but are not "
                f"brute-connected even at Jaccard >= {thr - MARGIN} "
                f"({mined.tag})")
    # the query surface serves exactly the mined clusters
    for d in (0, n // 2, n - 1):
        want = np.flatnonzero(sim.labels == sim.labels[d])
        assert np.array_equal(sim.versions_of(d), want), (mined.tag, d)


def test_session_serves_mined_answers(mined):
    """similar:/versions-of: through the full parse → plan → execute path
    return exactly the SimilarityIndex answers."""
    idx = NonPositionalIndex.build(mined.col.docs, store="vbyte_lzend",
                                   mine_similarity=True)
    s = Session(idx)
    for d in (0, idx.similarity.n_docs - 1):
        assert np.array_equal(s.execute(f"similar: {d}"),
                              idx.similarity.similar(d)), (mined.tag, d)
        assert np.array_equal(s.execute(f"versions-of: {d}"),
                              idx.similarity.versions_of(d)), (mined.tag, d)
    plan = s.plan("versions-of: 0")
    assert plan.route == "host" and plan.strategy == "cluster-versions"


# ----------------------------------------------------------------------
# estimator quality + kernel backend parity
# ----------------------------------------------------------------------
def test_minhash_estimates_within_error_bound(mined):
    """Every estimate sits within 4 standard errors (+1/num_perm
    quantization) of the true Jaccard."""
    sim, jac = mined.sim, mined.jac
    rng = np.random.default_rng(SEED)
    n = sim.n_docs
    for _ in range(200):
        i, j = rng.integers(n), rng.integers(n)
        if i == j:
            continue
        true_j = jac[i, j]
        est = est_jaccard(sim.sigs, int(i), int(j))
        bound = 4 * np.sqrt(true_j * (1 - true_j) / CONFIG.num_perm) \
            + 1 / CONFIG.num_perm
        assert abs(est - true_j) <= bound, (
            f"MinHash estimate {est:.3f} off true Jaccard {true_j:.3f} by "
            f"more than 4 stderr (bound {bound:.3f}, "
            f"num_perm={CONFIG.num_perm}, docs {i},{j}, {mined.tag})")


def test_signature_backends_agree(mined):
    """ref / jnp / kernel (interpret off-TPU) signature paths are
    bit-identical — the differential guarantee for the kernel family."""
    sets = [shingle_hashes(s, CONFIG.shingle) for s in mined.seqs[:12]]
    ref = signature_matrix(sets, CONFIG, backend="ref")
    for backend in ("jnp", "kernel"):
        got = signature_matrix(sets, CONFIG, backend=backend)
        assert got.dtype == ref.dtype and np.array_equal(got, ref), (
            f"minhash_sig backend {backend!r} drifts from ref "
            f"({mined.tag}): first mismatch row "
            f"{int(np.argmax((got != ref).any(axis=1)))}")


# ----------------------------------------------------------------------
# grammar errors + the referential backend's space win
# ----------------------------------------------------------------------
@pytest.mark.parametrize("query", ["similar: x", "similar:", "similar: 3 4",
                                   "versions-of: -1", "versions-of: 1.5"])
def test_malformed_doc_id_names_grammar(query):
    with pytest.raises(ValueError, match="non-negative integer doc id"):
        parse_query(query)
    with pytest.raises(ValueError, match="grammar"):
        parse_query(query)


def test_out_of_range_doc_id_names_grammar(mined):
    idx = NonPositionalIndex.build(mined.col.docs[:6], store="vbyte",
                                   mine_similarity=True)
    s = Session(idx)
    with pytest.raises(ValueError, match=r"valid ids 0\.\.5.*grammar"):
        s.execute("similar: 6")


def test_unmined_index_is_refused():
    idx = NonPositionalIndex.build(["a b c", "a b d"], store="vbyte")
    with pytest.raises(ValueError, match="mine_similarity=True"):
        Session(idx).execute("similar: 0")


def test_rlz_beats_best_universal_backend():
    """Acceptance: the structure-mining referential backend out-compresses
    the best universal one on the standard edit-rate-0.02 fixture."""
    col = generate_collection(n_articles=5, versions_per_article=20,
                              words_per_doc=200, edit_rate=0.02, seed=0)
    rlz = NonPositionalIndex.build(col.docs, store="rlz")
    lzend = NonPositionalIndex.build(col.docs, store="vbyte_lzend")
    assert rlz.space_fraction < lzend.space_fraction, (
        f"rlz space_fraction {rlz.space_fraction:.4f} does not beat "
        f"vbyte_lzend {lzend.space_fraction:.4f} on the edit-rate-0.02 "
        f"fixture (seed=0)")
