"""Storage-layer lockdown: mmap artifacts, verify policies, background
compaction, crash consistency, refresh/execute races.

The contracts under test:

* ``open_index(..., mmap=True)`` serves the persisted layout in place
  (:class:`~repro.core.storage.MappedListStore` for hook-less inverted
  backends) with answers **byte-identical** to the eager open, while
  materializing only a small fraction of the artifact;
* checksum policies — ``eager`` fails at open, ``lazy`` fails before the
  first posting is served (never after an answer), ``off`` never checks;
* ``IndexWriter.compact_async`` merges on a worker thread while the old
  segments keep serving, swaps atomically, fires ``on_swap`` exactly
  once, and mutating the writer mid-flight is a typed error;
* an interrupted commit leaves **no half-segment**: the manifest never
  references the dead build directory and resume discards it;
* ``Session.refresh()`` racing ``execute()`` from another thread always
  answers against exactly one committed snapshot — pre- or post-refresh,
  never a mix.
"""

import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core.artifact import ArtifactError, open_index, save_index
from repro.core.index import NonPositionalIndex
from repro.core.storage import BlobStore, CompactionHandle, MappedListStore
from repro.core.storage.compaction import CompactionError
from repro.core.writer import IndexWriter
from repro.serving.session import Session

BASE_SEED = int(os.environ.get("REPRO_DIFF_SEED", "20260727"))

DOCS_V1 = [
    "alpha beta gamma alpha beta",
    "alpha gamma delta epsilon gamma",
    "zebra quartz zebra nickel quartz",
    "beta delta nickel epsilon beta",
]
DOCS_V2 = [
    "alpha beta alpha gamma beta",
    "delta alpha epsilon beta gamma",
]

QUERIES = ["alpha", "alpha beta", "docs: gamma", "top3: alpha beta",
           "docs-top3: beta", "rank3: alpha delta", "docs: zebra"]


def make_writer(tmp_path, store="vbyte", positional=True, both=True):
    w = IndexWriter(tmp_path / "col", store=store, positional=positional)
    w.add_documents(DOCS_V1)
    w.commit()
    if both:
        w.add_documents(DOCS_V2)
        w.commit()
    return w


def corrupt_component(artifact_dir, name):
    """Flip bytes in one component blob without touching the manifest."""
    manifest = json.loads((artifact_dir / "manifest.json").read_text())
    blob = artifact_dir / manifest["components"][name]["file"]
    data = bytearray(blob.read_bytes())
    data[-1] ^= 0xFF
    blob.write_bytes(bytes(data))


# ----------------------------------------------------------------------
# mmap open: identity, residency, store selection
# ----------------------------------------------------------------------
def test_mmap_open_serves_mapped_store_byte_identical(tmp_path):
    idx = NonPositionalIndex.build(DOCS_V1 + DOCS_V2, store="vbyte")
    root = save_index(idx, tmp_path / "art")
    eager = open_index(root)
    mapped = open_index(root, mmap=True)
    assert isinstance(mapped.store, MappedListStore)
    assert not isinstance(eager.store, MappedListStore)
    for tid in range(len(idx.vocab)):
        assert np.array_equal(np.asarray(eager.store.get_list(tid)),
                              np.asarray(mapped.store.get_list(tid))), \
            f"(seed={BASE_SEED}, tid={tid}): mapped list diverges"
        assert eager.store.list_length(tid) == mapped.store.list_length(tid)
    assert mapped.store.n_lists == eager.store.n_lists


def test_mmap_session_answers_equal_eager(tmp_path):
    w = make_writer(tmp_path)
    eager = Session.open(w.path, device=False)
    mapped = Session.open(w.path, device=False, mmap=True)
    for q in QUERIES:
        assert np.array_equal(np.asarray(eager.execute(q)),
                              np.asarray(mapped.execute(q))), \
            f"(seed={BASE_SEED}, query={q!r}): mmap != eager"


def test_mmap_open_materializes_small_fraction(tmp_path):
    w = make_writer(tmp_path, positional=False)
    sess = Session.open(w.path, device=False, mmap=True)
    stores = [seg.session.index.blobstore for seg in sess._segments]
    assert stores and all(b.mmap for b in stores)
    frac = (sum(b.loaded_nbytes for b in stores)
            / sum(b.total_nbytes for b in stores))
    # only the vocab (bytes component) is materialized at open
    assert frac < 0.5, frac
    loaded_before = sum(b.loaded_nbytes for b in stores)
    sess.execute("alpha beta")  # paging, not loading: accounting unchanged
    assert sum(b.loaded_nbytes for b in stores) == loaded_before


def test_mmap_with_restore_hook_backend_still_works(tmp_path):
    """Backends with a compiled-state restore hook (repair_skip) adopt
    their packed arrays under mmap too — no MappedListStore, same
    answers."""
    w = make_writer(tmp_path, store="repair_skip")
    eager = Session.open(w.path, device=False)
    mapped = Session.open(w.path, device=False, mmap=True)
    seg_store = mapped._segments[0].session.index.store
    assert not isinstance(seg_store, MappedListStore)
    for q in QUERIES:
        assert np.array_equal(np.asarray(eager.execute(q)),
                              np.asarray(mapped.execute(q))), \
            f"(seed={BASE_SEED}, store=repair_skip, query={q!r})"


# ----------------------------------------------------------------------
# verify policies
# ----------------------------------------------------------------------
def test_verify_eager_fails_at_open(tmp_path):
    idx = NonPositionalIndex.build(DOCS_V1, store="vbyte")
    root = save_index(idx, tmp_path / "art")
    corrupt_component(root, "store.postings")
    with pytest.raises(ArtifactError, match="checksum mismatch.*store.postings"):
        open_index(root)  # default: eager


def test_verify_lazy_fails_before_first_answer(tmp_path):
    idx = NonPositionalIndex.build(DOCS_V1, store="vbyte")
    root = save_index(idx, tmp_path / "art")
    corrupt_component(root, "store.postings")
    mapped = open_index(root, mmap=True)  # lazy: open succeeds
    assert "store.postings" in mapped.blobstore.pending_verification
    with pytest.raises(ArtifactError, match="checksum mismatch.*store.postings"):
        mapped.store.get_list(0)  # first touch settles the pending set


def test_verify_lazy_settles_on_first_touch(tmp_path):
    idx = NonPositionalIndex.build(DOCS_V1, store="vbyte")
    root = save_index(idx, tmp_path / "art")
    mapped = open_index(root, mmap=True, verify="lazy")
    assert mapped.blobstore.pending_verification  # deferred at open
    mapped.store.get_list(0)
    assert not mapped.blobstore.pending_verification  # settled, once
    mapped.store.get_list(1)  # idempotent: no re-hash path to fail


def test_verify_off_never_checks(tmp_path):
    idx = NonPositionalIndex.build(DOCS_V1, store="vbyte")
    root = save_index(idx, tmp_path / "art")
    corrupt_component(root, "scoring.doc_lengths")
    opened = open_index(root, verify="off")  # corrupted yet silent, by request
    opened.store.get_list(0)


def test_verify_mode_validated(tmp_path):
    idx = NonPositionalIndex.build(DOCS_V1, store="vbyte")
    root = save_index(idx, tmp_path / "art")
    with pytest.raises(ValueError, match="unknown verify mode"):
        open_index(root, verify="sometimes")


def test_blobstore_accounting(tmp_path):
    idx = NonPositionalIndex.build(DOCS_V1, store="vbyte")
    root = save_index(idx, tmp_path / "art")
    manifest = json.loads((root / "manifest.json").read_text())
    blobs = BlobStore(root, manifest["components"], mmap=False)
    assert blobs.loaded_nbytes == 0 and blobs.loaded_fraction == 0.0
    blobs.get_all()
    assert blobs.loaded_nbytes > 0
    assert blobs.total_nbytes == sum(int(e["nbytes"])
                                     for e in manifest["components"].values())


# ----------------------------------------------------------------------
# background compaction
# ----------------------------------------------------------------------
def test_compact_async_equals_sync_compact(tmp_path):
    wa = make_writer(tmp_path / "a")
    wb = make_writer(tmp_path / "b")
    wa.compact()
    handle = wb.compact_async()
    meta = handle.wait(60)
    assert meta.n_docs == wa.segments[0].n_docs == len(DOCS_V1 + DOCS_V2)
    sa = Session.open(wa.path, device=False)
    sb = Session.open(wb.path, device=False)
    for q in QUERIES:
        assert np.array_equal(np.asarray(sa.execute(q)),
                              np.asarray(sb.execute(q))), \
            f"(seed={BASE_SEED}, query={q!r}): async compact diverged"


def test_serving_continues_during_compaction(tmp_path):
    """Queries served while the merge runs are byte-identical to the
    quiesced answers, before and after the swap."""
    w = make_writer(tmp_path)
    sess = Session.open(w.path, device=False, mmap=True)
    expected = [np.asarray(sess.execute(q)) for q in QUERIES]
    handle = w.compact_async(on_swap=sess.refresh)
    rounds = 0
    while not handle.done:
        for q, exp in zip(QUERIES, expected):
            assert np.array_equal(np.asarray(sess.execute(q)), exp), \
                f"(seed={BASE_SEED}, query={q!r}): drift during compaction"
        rounds += 1
    handle.wait(60)
    assert len(sess._segments) == 1  # on_swap refreshed the session
    for q, exp in zip(QUERIES, expected):
        assert np.array_equal(np.asarray(sess.execute(q)), exp), \
            f"(seed={BASE_SEED}, query={q!r}): drift after swap"


def test_on_swap_fires_exactly_once(tmp_path):
    w = make_writer(tmp_path)
    fired = []
    handle = w.compact_async(on_swap=lambda: fired.append(1))
    handle.wait(60)
    assert fired == [1]


def test_writer_mutation_during_compaction_is_typed_error(tmp_path):
    w = make_writer(tmp_path)
    gate = threading.Event()
    orig = w._merged_indexes

    def slow_merge(segments):
        gate.wait(10)
        return orig(segments)

    w._merged_indexes = slow_merge
    handle = w.compact_async()
    w.add_documents(["held back"])
    try:
        assert w.compacting
        with pytest.raises(RuntimeError, match="background compaction"):
            w.commit()
        with pytest.raises(RuntimeError, match="background compaction"):
            w.compact()
        with pytest.raises(RuntimeError, match="background compaction"):
            w.compact_async()
    finally:
        gate.set()
    handle.wait(60)
    assert not w.compacting
    w.commit()  # the buffered doc was preserved and commits fine now
    assert w.n_docs == len(DOCS_V1 + DOCS_V2) + 1


def test_failed_compaction_leaves_segments_intact(tmp_path):
    w = make_writer(tmp_path)
    before = [s.name for s in w.segments]

    def exploding(segments):
        raise RuntimeError("merge wedged")

    w._merged_indexes = exploding
    handle = w.compact_async()
    with pytest.raises(CompactionError, match="merge wedged"):
        handle.wait(60)
    assert handle.failed
    assert [s.name for s in w.segments] == before
    seg_root = w.path / "segments"
    assert sorted(p.name for p in seg_root.iterdir()) == before  # no debris
    for q in QUERIES:  # still servable
        Session.open(w.path, device=False).execute(q)
        break


def test_compaction_handle_timeout_is_typed(tmp_path):
    gate = threading.Event()
    handle = CompactionHandle(lambda: gate.wait(10)).start()
    with pytest.raises(TimeoutError, match="still running"):
        handle.wait(0.05)
    gate.set()
    handle.wait(10)


# ----------------------------------------------------------------------
# crash consistency
# ----------------------------------------------------------------------
def test_interrupted_commit_leaves_no_half_segment(tmp_path, monkeypatch):
    import repro.core.writer as writer_mod

    w = make_writer(tmp_path, both=False)
    calls = {"n": 0}
    orig = writer_mod.save_index

    def failing_save(idx, path):
        calls["n"] += 1
        if calls["n"] > 1:  # let nonpositional through, kill positional
            raise OSError("injected mid-commit failure")
        return orig(idx, path)

    monkeypatch.setattr(writer_mod, "save_index", failing_save)
    w.add_documents(DOCS_V2)
    with pytest.raises(OSError, match="injected"):
        w.commit()
    monkeypatch.setattr(writer_mod, "save_index", orig)
    # the manifest never adopted the dead segment and no dir survives
    assert [s.name for s in w.segments] == ["seg-000000"]
    seg_root = w.path / "segments"
    assert sorted(p.name for p in seg_root.iterdir()) == ["seg-000000"]
    resumed = IndexWriter.open(w.path)
    assert resumed.n_docs == len(DOCS_V1)
    Session.open(w.path, device=False).execute("alpha")


def test_resume_discards_orphaned_build_dirs(tmp_path):
    """A hard crash (no in-process cleanup) leaves ``.tmp-*`` /
    ``.compact-*`` dirs behind; resume removes them and never serves
    them."""
    w = make_writer(tmp_path, both=False)
    seg_root = w.path / "segments"
    (seg_root / ".tmp-seg-000001").mkdir()
    (seg_root / ".tmp-seg-000001" / "junk.bin").write_bytes(b"xx")
    (seg_root / ".compact-seg-000001").mkdir()
    # a renamed-but-never-adopted dir (crash between rename and manifest)
    (seg_root / "seg-000099").mkdir()
    resumed = IndexWriter.open(w.path)
    assert sorted(p.name for p in seg_root.iterdir()) == ["seg-000000"]
    assert [s.name for s in resumed.segments] == ["seg-000000"]
    resumed.add_documents(DOCS_V2)
    resumed.commit()
    assert resumed.n_docs == len(DOCS_V1 + DOCS_V2)


# ----------------------------------------------------------------------
# refresh() racing execute() across threads
# ----------------------------------------------------------------------
def test_refresh_racing_execute_yields_consistent_snapshots(tmp_path):
    """One thread refreshes through commits and a compaction while another
    executes continuously: every answer must equal the pre- or the
    post-refresh snapshot for its query — never a mix, never an error."""
    w = make_writer(tmp_path, both=False)
    sess = Session.open(w.path, device=False, mmap=True)

    q = "docs: alpha"
    snap_before = np.asarray(Session.open(w.path, device=False).execute(q))
    w_after = IndexWriter.open(w.path)
    w_after.add_documents(DOCS_V2)
    # legal answers: against 1 segment, against 2, or post-compaction
    legal = [snap_before]

    errors: list[BaseException] = []
    answers: list[np.ndarray] = []
    stop = threading.Event()

    def executor():
        try:
            while not stop.is_set():
                answers.append(np.asarray(sess.execute(q)))
        except BaseException as e:  # pragma: no cover - failure surface
            errors.append(e)

    t = threading.Thread(target=executor)
    t.start()
    try:
        w_after.commit()
        sess.refresh()
        legal.append(np.asarray(Session.open(w.path, device=False).execute(q)))
        time.sleep(0.05)
        handle = w_after.compact_async(on_swap=sess.refresh)
        handle.wait(60)
        legal.append(np.asarray(Session.open(w.path, device=False).execute(q)))
        time.sleep(0.05)
    finally:
        stop.set()
        t.join(30)
    assert not errors, errors
    assert len(answers) > 0
    for i, ans in enumerate(answers):
        assert any(np.array_equal(ans, snap) for snap in legal), \
            (f"(seed={BASE_SEED}) answer {i} is a cross-snapshot mix: "
             f"{ans} not in {[s.tolist() for s in legal]}")
    # the executing thread did observe the post-commit state eventually
    assert any(np.array_equal(answers[-1], snap) for snap in legal[1:])
