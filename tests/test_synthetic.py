"""Streaming synthetic collections: determinism, bounded chunks,
repetitiveness, and ingestion into the segmented writer.

The generator's contract (``repro.data.synthetic``): the same spec always
streams the same documents in the same chunk boundaries; memory is
bounded by the chunk plus per-article branch tails (the collection is
never materialized inside the generator); consecutive versions are
near-copies at the configured edit rate — the repetitiveness the scale
benchmarks (and the paper's premise) rely on.
"""

import difflib
import os

import numpy as np
import pytest

from repro.core.writer import IndexWriter
from repro.data.synthetic import SyntheticSpec, ingest_stream, stream_collection
from repro.serving.session import Session

BASE_SEED = int(os.environ.get("REPRO_DIFF_SEED", "20260727"))

SPEC = SyntheticSpec(n_articles=4, versions_per_article=6, words_per_doc=40,
                     vocab_size=200, chunk_docs=5, seed=BASE_SEED % 9973)


def all_docs(spec):
    return [d for chunk in stream_collection(spec) for d in chunk]


def test_stream_is_deterministic_and_complete():
    docs1 = all_docs(SPEC)
    docs2 = all_docs(SPEC)
    assert docs1 == docs2
    assert len(docs1) == SPEC.n_docs == 24


def test_chunks_are_bounded():
    sizes = [len(c) for c in stream_collection(SPEC)]
    assert all(s <= SPEC.chunk_docs for s in sizes)
    assert all(s == SPEC.chunk_docs for s in sizes[:-1])  # only tail partial
    assert sum(sizes) == SPEC.n_docs


def test_seed_and_branching_change_the_collection():
    other_seed = all_docs(SyntheticSpec(**{**SPEC.config(),
                                           "seed": SPEC.seed + 1}))
    branched = all_docs(SyntheticSpec(**{**SPEC.config(), "branching": 3}))
    base = all_docs(SPEC)
    assert other_seed != base
    assert branched != base
    assert len(other_seed) == len(branched) == len(base)


def test_versions_are_near_copies():
    """Round-robin order: doc (v * n_articles + a) is version v of
    article a; consecutive versions must be highly similar, different
    articles must not be."""
    docs = all_docs(SPEC)
    n = SPEC.n_articles
    same = difflib.SequenceMatcher(None, docs[0], docs[n]).ratio()
    cross = difflib.SequenceMatcher(None, docs[0], docs[1]).ratio()
    assert same > 0.8, f"(seed={BASE_SEED}) versions not repetitive: {same}"
    assert same > cross, (same, cross)


def test_invalid_spec_is_typed_error():
    with pytest.raises(ValueError, match="branching"):
        next(stream_collection(SyntheticSpec(branching=0)))
    with pytest.raises(ValueError, match="chunk_docs"):
        next(stream_collection(SyntheticSpec(chunk_docs=0)))


def test_ingest_stream_builds_servable_segments(tmp_path):
    w = IndexWriter(tmp_path / "col", store="vbyte", positional=False)
    n = ingest_stream(w, SPEC, commit_every=2)
    assert n == SPEC.n_docs
    assert w.n_docs == SPEC.n_docs
    assert len(w.segments) == 3  # ceil(24 / 5) = 5 chunks -> 3 commits
    sess = Session.open(w.path, device=False, mmap=True)
    # differential: the streamed collection equals the materialized one
    docs = all_docs(SPEC)
    word = docs[0].split()[0]
    expected = np.asarray(sorted(i for i, d in enumerate(docs)
                                 if word in d.split()), dtype=np.int64)
    got = np.asarray(sess.execute(f"docs: {word}"))
    assert np.array_equal(got, expected), \
        f"(seed={BASE_SEED}, word={word!r}): {got} != {expected}"


def test_ingest_stream_max_docs_truncates(tmp_path):
    w = IndexWriter(tmp_path / "col", store="vbyte", positional=False)
    n = ingest_stream(w, SPEC, max_docs=7)
    assert n == 7 and w.n_docs == 7
    docs = all_docs(SPEC)[:7]
    sess = Session.open(w.path, device=False)
    word = docs[0].split()[0]
    expected = np.asarray(sorted(i for i, d in enumerate(docs)
                                 if word in d.split()), dtype=np.int64)
    assert np.array_equal(np.asarray(sess.execute(f"docs: {word}")), expected)


def test_approx_bytes_in_right_ballpark():
    docs = all_docs(SPEC)
    actual = sum(len(d) for d in docs)
    approx = SPEC.approx_bytes()
    assert 0.3 * actual < approx < 3 * actual, (approx, actual)
