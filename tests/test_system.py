"""End-to-end system behaviour: collection -> compressed indexes -> queries
-> serving engine; anchored TPU path == CPU skipping path; configs/dry-run
plumbing sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, all_cells, get_config
from repro.core.anchors import build_anchored, member_batch
from repro.core.index import NonPositionalIndex, PositionalIndex
from repro.data import generate_collection
from repro.serving.engine import QueryEngine


def test_forty_cells_defined():
    cells = all_cells()
    assert len(cells) == 40
    for arch, shape in cells:
        specs = get_config(arch).input_specs(shape)
        assert specs, (arch, shape)
        for k, v in specs.items():
            assert all(d > 0 for d in v.shape), (arch, shape, k)


def test_reduced_configs_exist():
    for arch in ASSIGNED_ARCHS:
        r = get_config(arch).reduced()
        assert r is not None


def test_end_to_end_search(small_collection):
    idx = NonPositionalIndex.build(small_collection.docs, store="repair_skip")
    engine = QueryEngine(idx)
    words = [w for w in idx.vocab.id_to_token[:20]]
    hits = engine.conjunctive([words[1], words[4]])
    # every reported doc really contains both words
    for d in hits.tolist():
        low = small_collection.docs[d].lower()
        assert words[1] in low and words[4] in low
    ranked = engine.ranked_and([words[1], words[4]], k=3)
    assert len(ranked) <= 3
    assert set(ranked.tolist()) <= set(hits.tolist())


def test_anchored_path_matches_cpu_path(small_collection):
    idx = NonPositionalIndex.build(small_collection.docs, store="repair_skip")
    store = idx.store
    lists = [store.get_list(i) for i in range(min(25, store.n_lists))]
    aidx = build_anchored(lists)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, len(lists), 200).astype(np.int32)
    vals = rng.integers(0, idx.n_docs + 5, 200).astype(np.int32)
    got = np.asarray(member_batch(aidx, jnp.asarray(ids), jnp.asarray(vals)))
    ref = np.asarray([int(v) in set(lists[i].tolist()) for i, v in zip(ids, vals)])
    assert np.array_equal(got, ref)


def test_positional_and_nonpositional_consistency(small_collection):
    np_idx = NonPositionalIndex.build(small_collection.docs, store="vbyte",
                                      case_fold=False, drop_stopwords=False)
    pos_idx = PositionalIndex.build(small_collection.docs, store="vbyte")
    w = [t for t in pos_idx.vocab.id_to_token if t.isalpha()][5]
    pos_hits = pos_idx.query_word(w)
    docs = np.unique(pos_idx.positions_to_docs(pos_hits)[0])
    np_hits = np_idx.query_word(w)
    assert np.array_equal(docs, np_hits)


def test_compression_improves_with_repetitiveness():
    frac = {}
    for edit_rate in (0.002, 0.2):
        col = generate_collection(n_articles=4, versions_per_article=10,
                                  words_per_doc=100, edit_rate=edit_rate, seed=2)
        idx = NonPositionalIndex.build(col.docs, store="repair_skip")
        frac[edit_rate] = idx.space_fraction
    assert frac[0.002] < frac[0.2]


def test_collection_stats_table():
    col = generate_collection(n_articles=3, versions_per_article=5, words_per_doc=50)
    s = col.stats()
    assert s["versions"] == 15 and s["articles"] == 3
    assert s["versions_per_article"] == 5.0
