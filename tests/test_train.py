"""Optimizer, checkpointing, fault tolerance, grad compression (host side)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.sharding.compat import AxisType, make_mesh, shard_map
from repro.train.loop import TrainLoop, WatchdogStats
from repro.train.optimizer import OptConfig, opt_init, opt_update, schedule


def quad_problem():
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    return params, loss


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_converges(kind):
    cfg = OptConfig(kind=kind, lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=10000)
    params, loss = quad_problem()
    state = opt_init(cfg, params)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = opt_update(cfg, params, grads, state)
    assert float(loss(params)) < 1e-2, kind


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.asarray(100))) <= 0.11


def test_grad_clipping():
    from repro.train.optimizer import clip_by_global_norm, global_norm

    tree = {"a": jnp.full((10,), 100.0)}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(n) > 100


# ----------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)}, "step": jnp.asarray(7)}
    ck.save(7, state)
    restored, step = ck.restore(state)
    assert step == 7
    assert np.array_equal(np.asarray(restored["params"]["w"]), np.arange(6.0).reshape(2, 3))


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = {"x": jnp.asarray(1.0)}
    for s in (10, 20, 30):
        ck.save(s, state)
    assert ck.all_steps() == [20, 30]
    assert ck.latest_step() == 30


def test_corrupt_checkpoint_skipped(tmp_path):
    """Node-failure path: newest snapshot corrupted -> fall back."""
    ck = Checkpointer(str(tmp_path), keep=5, async_save=False)
    state = {"x": jnp.asarray(1.0)}
    ck.save(1, state)
    ck.save(2, {"x": jnp.asarray(2.0)})
    # corrupt step 2
    d = os.path.join(str(tmp_path), "step_0000000002")
    fname = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, fname), "wb") as f:
        f.write(b"garbage")
    restored, step = ck.restore_latest_valid(state)
    assert step == 1
    assert float(restored["x"]) == 1.0


def test_async_save_surfaces_errors(tmp_path):
    ck = Checkpointer(str(tmp_path / "sub"), keep=1, async_save=True)
    ck.save(1, {"x": jnp.asarray(1.0)})
    ck.wait()
    assert ck.latest_step() == 1


def test_elastic_reshard_identity():
    """Checkpoint -> reshard to a different (host) mesh keeps values."""
    from repro.checkpoint.checkpointer import reshard
    from repro.launch.mesh import make_local_mesh
    from jax.sharding import PartitionSpec as P

    mesh = make_local_mesh(1, 1)
    state = {"w": jnp.arange(8.0).reshape(2, 4)}
    specs = {"w": P(None, None)}
    out = reshard(state, mesh, specs)
    assert np.array_equal(np.asarray(out["w"]), np.asarray(state["w"]))


# ----------------------------------------------------------------------
def test_watchdog_flags_stragglers():
    w = WatchdogStats()
    for s in range(10):
        assert not w.update(s, 0.1)
    assert w.update(10, 1.0)  # 10x the EWMA
    assert w.stragglers == [10]


def test_train_loop_resume(tmp_path):
    cfg = OptConfig(lr=0.05, warmup_steps=0, total_steps=1000, weight_decay=0.0)
    params, loss = quad_problem()

    def step(state, batch):
        grads = jax.grad(loss)(state["params"])
        p, o, extra = opt_update(cfg, state["params"], grads, state["opt"])
        return {"params": p, "opt": o, "step": state["step"] + 1}, {
            "loss": loss(state["params"]), **extra}

    def data():
        while True:
            yield {}

    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    state0 = {"params": params, "opt": opt_init(cfg, params), "step": jnp.asarray(0)}
    loop = TrainLoop(train_step=jax.jit(step), data_iter=data(), checkpointer=ck, ckpt_every=5)
    state, logs = loop.run(state0, 12)
    assert ck.latest_step() == 10
    # resume and continue
    restored, start = TrainLoop.resume_or_init(ck, state0)
    assert start == 10
    state2, logs2 = loop.run(restored, 5, start_step=start)
    assert logs2[-1]["loss"] < logs[0]["loss"]


# ----------------------------------------------------------------------
def test_grad_compression_shapes():
    """Quantized psum approximates the true sum (single-device axis)."""
    from functools import partial

    from repro.train.grad_compression import psum_int8, psum_topk

    mesh = make_mesh((1,), ("d",), axis_types=(AxisType.Auto,))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(37, 5)), jnp.float32)

    @partial(shard_map, mesh=mesh,
             in_specs=jax.sharding.PartitionSpec(), out_specs=jax.sharding.PartitionSpec())
    def f(x):
        return psum_int8(x, "d")

    got = f(x)
    assert float(jnp.max(jnp.abs(got - x))) < 2e-2  # quantization error only

    @partial(shard_map, mesh=mesh,
             in_specs=jax.sharding.PartitionSpec(), out_specs=(jax.sharding.PartitionSpec(),) * 2)
    def g(x):
        return psum_topk(x, "d", k_frac=1.0)

    total, resid = g(x)
    assert float(jnp.max(jnp.abs(total - x))) < 1e-6  # k=100%: lossless
